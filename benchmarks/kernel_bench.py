"""Kernel-registry benchmark: dispatch every registered op, autotune the
tile spaces, and compare tuned vs legacy-fixed tile configs.

Writes ``BENCH_kernels.json`` so CI accumulates a perf trajectory:

    {"meta": {...}, "registry": {op: dispatch plan}, "autotune": {...},
     "rows": [{"name", "us", ...}]}

``--smoke`` (CI) uses tiny shapes on the interpret impls so the sweep
finishes in seconds on a CPU runner; numbers are regression tracking, not
roofline claims.  The headline comparison: the tuned decode-shape
``dequant_matmul`` config (rows clamped to the live batch) vs the old
fixed ``bm=256, bn=256, bk=512`` tiles that padded every 1-8 row decode
matmul to 256 rows.

Run: PYTHONPATH=src python -m benchmarks.kernel_bench [--smoke] [--out F]
"""

from __future__ import annotations

import argparse
import json
import platform as _platform
import time


def _time_call(fn, *args, repeats=3, warmup=1, **kwargs) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kwargs))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def bench_dequant_matmul_tiles(shapes, *, tune_impl: str, repeats: int,
                               rows: list) -> None:
    """Tuned (cache) tiles vs the legacy fixed bm=256,bn=256,bk=512."""
    import numpy as np
    from repro import kernels

    op = kernels.get("dequant_matmul")
    spec = kernels.spec("dequant_matmul")
    impl = spec.impls[tune_impl]
    for m, k, n in shapes:
        (x, wq, sc), _ = spec.example_inputs((m, k, n))
        # the old hard-coded tiles (bn/bk clamped so small layers compile)
        fixed = {"bm": 256, "bn": min(256, -(-n // 128) * 128),
                 "bk": min(512, -(-k // 128) * 128)}
        t_fixed = _time_call(impl.fn, x, wq, sc, repeats=repeats, **fixed)
        pol = kernels.KernelPolicy().override("dequant_matmul", tune_impl)
        plan = op.plan(x, wq, sc, policy=pol)
        tiles = dict(plan.tiles)
        t_tuned = _time_call(impl.fn, x, wq, sc, repeats=repeats, **tiles)
        ref = np.asarray(spec.oracle(x, wq, sc))
        got = np.asarray(impl.fn(x, wq, sc, **tiles))
        np.testing.assert_allclose(got, ref, rtol=2e-4,
                                   atol=2e-4 * np.abs(ref).max())
        rows.append({
            "name": f"dequant_matmul/m{m}_k{k}_n{n}",
            "impl": tune_impl, "fixed_tiles": fixed, "fixed_us":
            round(t_fixed, 1), "tuned_tiles": tiles, "tuned_us":
            round(t_tuned, 1), "cache_hit": plan.cache_hit,
            "tuned_vs_fixed_speedup": round(t_fixed / max(t_tuned, 1e-9), 3),
        })


def bench_registry_dispatch(smoke: bool, rows: list) -> dict:
    """One dispatched call per registered op; records the chosen plan and
    checks the result against the op's oracle."""
    import jax.numpy as jnp
    import numpy as np
    from repro import kernels

    plans: dict = {}

    # dequant_matmul + flash_attention + rd_quant via example_inputs
    examples = {
        "dequant_matmul": (4, 256, 256) if smoke else (8, 2048, 1024),
        "flash_attention": ((1, 64, 64, 2, 2, 32) if smoke
                            else (2, 512, 512, 8, 4, 64)),
        "rd_quant": (1 << 12,) if smoke else (1 << 16,),
    }
    for name, shape in examples.items():
        op = kernels.get(name)
        args, kwargs = kernels.spec(name).example_inputs(shape)
        plan = op.plan(*args, **kwargs)
        us = _time_call(op, *args, repeats=2, **kwargs)
        plans[name] = {"impl": plan.impl, "platform": plan.platform,
                       "tiles": dict(plan.tiles), "cache_hit": plan.cache_hit}
        rows.append({"name": f"{name}/dispatch", "us": round(us, 1),
                     "impl": plan.impl, "shape": list(shape)})

    # embed_lookup_q8 (no example_inputs: tiny inline case)
    rng = np.random.default_rng(0)
    leaf = {"q8": jnp.asarray(rng.integers(-127, 127, (4096, 128)), jnp.int8),
            "q8s": jnp.asarray(rng.random(128) * 0.01 + 1e-4, jnp.float32)}
    toks = jnp.asarray(rng.integers(0, 4096, (4, 64)), jnp.int32)
    op = kernels.get("embed_lookup_q8")
    plan = op.plan(leaf, toks, jnp.float32)
    us = _time_call(op, leaf, toks, jnp.float32, repeats=2)
    got = np.asarray(op(leaf, toks, jnp.float32))
    want = np.asarray(kernels.spec("embed_lookup_q8").oracle(
        leaf, toks, jnp.float32))
    np.testing.assert_allclose(got, want, rtol=1e-6)
    plans["embed_lookup_q8"] = {"impl": plan.impl, "platform": plan.platform}
    rows.append({"name": "embed_lookup_q8/dispatch", "us": round(us, 1),
                 "impl": plan.impl, "shape": [4, 64]})
    return plans


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny interpret-mode shapes (CI)")
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()

    import jax
    from repro import kernels
    from repro.kernels import tune

    backend = jax.default_backend()
    tune_impl = "pallas" if backend == "tpu" else "interpret"

    if args.smoke:
        dm_shapes = [(1, 256, 256), (4, 256, 256), (8, 256, 256),
                     (128, 256, 256)]
        dmg_shapes = [(4, 8, 256, 256), (8, 16, 128, 256)]
        fa_shapes = [(1, 64, 64, 2, 2, 32)]
        rd_shapes = [(1 << 12,)]
    else:
        dm_shapes = [(1, 2048, 1024), (8, 2048, 1024), (256, 2048, 1024),
                     (1024, 2048, 1024)]
        dmg_shapes = [(8, 64, 2048, 1024), (64, 32, 1024, 512)]
        fa_shapes = [(2, 512, 512, 8, 4, 64), (1, 2048, 2048, 8, 4, 128)]
        rd_shapes = [(1 << 16,), (1 << 20,)]

    t0 = time.time()
    autotune_results = {
        "dequant_matmul": tune.autotune(
            "dequant_matmul", dm_shapes, impl=tune_impl,
            repeats=args.repeats, force=True),
        "dequant_matmul_grouped": tune.autotune(
            "dequant_matmul_grouped", dmg_shapes, impl=tune_impl,
            repeats=max(args.repeats - 1, 1), force=True),
        "flash_attention": tune.autotune(
            "flash_attention", fa_shapes, impl=tune_impl,
            repeats=max(args.repeats - 1, 1), force=True),
        "rd_quant": tune.autotune(
            "rd_quant", rd_shapes, impl=tune_impl,
            repeats=max(args.repeats - 1, 1), force=True),
    }
    t_tune = time.time() - t0

    rows: list = []
    kernels.clear_dispatch_report()
    plans = bench_registry_dispatch(args.smoke, rows)
    bench_dequant_matmul_tiles(dm_shapes, tune_impl=tune_impl,
                               repeats=args.repeats, rows=rows)

    out = {
        "meta": {
            "backend": backend, "python": _platform.python_version(),
            "jax": jax.__version__, "smoke": bool(args.smoke),
            "autotune_s": round(t_tune, 2),
            "tuning_cache": str(tune.default_cache_path()),
            "ops": kernels.available_ops(),
        },
        "registry": plans,
        "autotune": autotune_results,
        "dispatch_report": kernels.dispatch_report(),
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    decode = [r for r in rows if r["name"].startswith("dequant_matmul/m")
              and int(r["name"].split("/m")[1].split("_")[0]) <= 8]
    for r in decode:
        print(f"{r['name']}: fixed {r['fixed_us']}us -> tuned "
              f"{r['tuned_us']}us (x{r['tuned_vs_fixed_speedup']})")
    print(f"wrote {args.out} ({len(rows)} rows, autotune {t_tune:.1f}s)")


if __name__ == "__main__":
    main()
