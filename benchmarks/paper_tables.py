"""One benchmark per paper table.

Table I  — compression ratio at (near-)no accuracy loss: DC-v1, DC-v2 vs
           weighted-Lloyd and uniform quantization, each with their best
           lossless backend (scalar Huffman / CSR-Huffman / bzip2), on
           dense and VD-sparsified models.
Table II — bits/param at fixed step sizes across quantizers.
Table III— lossless coder shoot-out on fixed quantized tensors (CABAC vs
           scalar Huffman vs CSR-Huffman vs bzip2 vs EPMD entropy).
Fig. 8   — rate-accuracy curve (lambda sweep).
"""

from __future__ import annotations

import numpy as np

from repro.core import binarization as B
from repro.core.cabac import RangeEncoder
from repro.core.csr import bzip2_size_bits, csr_huffman_size_bits
from repro.core.deepcabac import (compress_dc_v1, compress_dc_v2,
                                  quantize_tensor_rd)
from repro.core.huffman import epmd_entropy_bits, scalar_huffman_size_bits
from repro.core.quant import nearest_level, uniform_quantize, weighted_lloyd

from .tasks import flat_weights, rebuild


def _cabac_bits(levels: np.ndarray) -> int:
    enc = RangeEncoder(B.make_contexts())
    B.encode_levels(enc, np.asarray(levels).ravel())
    return 8 * len(enc.finish())


def _quantize_model(flat, method, *, delta=None, k=256, lam=0.0,
                    sigma=None):
    """Returns (levels_or_assignments dict, dequantized dict, bits fn)."""
    deq, bits = {}, 0
    for name, w in flat.items():
        if w.ndim < 2:
            deq[name] = w
            bits += 32 * w.size
            continue
        if method == "uniform":
            a, centers = uniform_quantize(w.ravel(), k)
            deq[name] = centers[a].reshape(w.shape).astype(w.dtype)
            bits += min(scalar_huffman_size_bits(a),
                        bzip2_size_bits(a),
                        csr_huffman_size_bits(a.reshape(w.shape[0], -1)))
            bits += 32 * k  # codebook
        elif method == "lloyd":
            f = None if sigma is None else \
                (1.0 / (np.asarray(sigma[name]).ravel() ** 2 + 1e-20))
            res = weighted_lloyd(w.ravel(), f, k, lam, iters=12)
            deq[name] = res.centers[res.assignments].reshape(
                w.shape).astype(w.dtype)
            bits += min(scalar_huffman_size_bits(res.assignments),
                        bzip2_size_bits(res.assignments),
                        csr_huffman_size_bits(
                            res.assignments.reshape(w.shape[0], -1)))
            bits += 32 * k
        else:
            raise ValueError(method)
    return deq, bits


def table1(fixtures: dict) -> list[dict]:
    """fixtures: name -> (flat weights, sigma|None, accuracy fn on flat,
    template params).  Returns rows with ratio (%) at accuracy within 0.5pp
    of the original (paper protocol)."""
    rows = []
    for name, (flat, sigma, acc_fn, _tmpl) in fixtures.items():
        orig_acc = acc_fn(flat)
        orig_bits = 32 * sum(w.size for w in flat.values())
        floor = orig_acc - 0.005
        row = {"model": name, "orig_acc": orig_acc,
               "orig_mb": orig_bits / 8 / 2**20}

        # DC-v2: delta/lambda grid, smallest blob above the floor
        wmax = max(float(np.abs(w).max()) for w in flat.values()
                   if w.ndim >= 2)
        best = None
        for frac in [0.5, 0.35, 0.25, 0.12, 0.06, 0.03, 0.015, 0.008]:
            for lam in [0.0, 1e-4, 1e-3, 1e-2]:
                res = compress_dc_v2(flat, delta=frac * wmax, lam=lam)
                if acc_fn(res.reconstructed()) >= floor:
                    if best is None or len(res.blob) < len(best.blob):
                        best = res
            if best is not None:
                break   # coarser deltas failed; finer only grow the blob
        if best is None:
            best = compress_dc_v2(flat, delta=0.004 * wmax, lam=0.0)
        row["dc_v2_pct"] = 100 * 8 * len(best.blob) / orig_bits
        row["dc_v2_acc"] = acc_fn(best.reconstructed())

        # DC-v1 (needs sigma; falls back to a floored |w|-proxy if absent —
        # per-layer sigma_min must not collapse to ~0 or eq.12 degenerates)
        if sigma is not None:
            sig = sigma
        else:
            sig = {k: np.maximum(0.1 * np.abs(v),
                                 0.05 * v.std() if v.ndim >= 2 else 1.0)
                   for k, v in flat.items()}
        best1 = None
        for s in [0.0, 8.0, 32.0, 128.0, 512.0, 2048.0]:
            for lam in [0.0, 1e-4]:
                res = compress_dc_v1(flat, sig, s=s, lam=lam)
                if acc_fn(res.reconstructed()) >= floor:
                    if best1 is None or len(res.blob) < len(best1.blob):
                        best1 = res
        if best1 is not None:
            row["dc_v1_pct"] = 100 * 8 * len(best1.blob) / orig_bits
            row["dc_v1_acc"] = acc_fn(best1.reconstructed())

        # Lloyd + best lossless
        for method, key in [("lloyd", "lloyd"), ("uniform", "uniform")]:
            got = None
            for k in [16, 32, 64, 256]:
                deq, bits = _quantize_model(flat, method, k=k, sigma=sigma)
                if acc_fn(deq) >= floor:
                    got = (bits, acc_fn(deq))
                    break
            if got is None:
                deq, bits = _quantize_model(flat, method, k=1024,
                                            sigma=sigma)
                got = (bits, acc_fn(deq))
            row[f"{key}_pct"] = 100 * got[0] / orig_bits
            row[f"{key}_acc"] = got[1]
        rows.append(row)
    return rows


def table2(flat: dict, sigma: dict | None, step_fracs=(0.05, 0.02, 0.005)
           ) -> list[dict]:
    """Average bits/param at fixed step sizes (paper Table II)."""
    rows = []
    big = {k: w for k, w in flat.items() if w.ndim >= 2}
    n_params = sum(w.size for w in big.values())
    wmax = max(float(np.abs(w).max()) for w in big.values())
    for frac in step_fracs:
        step = frac * wmax
        row = {"step": step}
        for method in ["dc_v1", "dc_v2", "lloyd", "uniform"]:
            total = 0.0
            for name, w in big.items():
                if method in ("dc_v1", "dc_v2"):
                    fim = None
                    if method == "dc_v1" and sigma is not None:
                        fim = 1.0 / (np.asarray(sigma[name]) ** 2 + 1e-20)
                    qt = quantize_tensor_rd(w, step, 5e-5, importance=fim)
                    total += _cabac_bits(qt.levels)
                elif method == "uniform":
                    lv = nearest_level(w.ravel(), step)
                    total += epmd_entropy_bits(lv)
                else:
                    k = max(int(2 * np.abs(w).max() / step) + 1, 2)
                    res = weighted_lloyd(w.ravel(), None, min(k, 256),
                                         5e-5, iters=8)
                    total += epmd_entropy_bits(res.assignments)
            row[method] = total / n_params
        rows.append(row)
    return rows


def table3(flat: dict) -> list[dict]:
    """Lossless coder comparison on three quantized versions."""
    big = {k: w for k, w in flat.items() if w.ndim >= 2}
    wmax = max(float(np.abs(w).max()) for w in big.values())
    step = 0.02 * wmax
    rows = []
    for qname in ["uniform", "lloyd", "dc_v2"]:
        levels = {}
        for name, w in big.items():
            if qname == "uniform":
                levels[name] = nearest_level(w, step)
            elif qname == "dc_v2":
                levels[name] = quantize_tensor_rd(w, step, 1e-4).levels
            else:
                res = weighted_lloyd(w.ravel(), None, 64, 1e-4, iters=8)
                # map centers to the nearest integer grid for fair coding
                lv = np.rint(res.centers / step).astype(np.int64)
                levels[name] = lv[res.assignments].reshape(w.shape)
        n = sum(v.size for v in levels.values())
        row = {"quantizer": qname}
        row["huffman"] = sum(scalar_huffman_size_bits(v)
                             for v in levels.values()) / n
        row["csr_huffman"] = sum(
            csr_huffman_size_bits(v.reshape(v.shape[0], -1))
            for v in levels.values()) / n
        row["bzip2"] = sum(bzip2_size_bits(v) for v in levels.values()) / n
        row["cabac"] = sum(_cabac_bits(v) for v in levels.values()) / n
        row["entropy"] = sum(epmd_entropy_bits(v)
                             for v in levels.values()) / n
        rows.append(row)
    return rows


def fig8_rate_accuracy(flat: dict, acc_fn, lambdas=(0.0, 1e-5, 1e-4, 5e-4,
                                                    2e-3, 1e-2)) -> list:
    big_max = max(float(np.abs(w).max()) for w in flat.values()
                  if w.ndim >= 2)
    rows = []
    for lam in lambdas:
        res = compress_dc_v2(flat, delta=0.02 * big_max, lam=lam)
        rows.append({"lam": lam,
                     "bits_per_param": res.report["bits_per_param"],
                     "acc": acc_fn(res.reconstructed())})
    return rows
