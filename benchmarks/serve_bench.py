"""Serving-throughput smoke benchmark: tokens/s through ServeSession.

Measures the request-level serving path end to end on the smoke config —
prefill and decode split out per backend — and writes ``BENCH_serve.json``
so CI accumulates a perf trajectory.  Numbers are host-CPU smoke-scale
(regression tracking, not roofline claims; see the dry-run analysis for
TPU projections).

Compressed-resident serving rows: ``bf16_dequant`` serves the *quantized*
model dequantized at admission (the bf16-resident baseline with the same
numerics), ``q8`` serves it q8-resident (int8 levels + scales stay in HBM,
every matmul through the fused dequant kernels).  Each row carries its
measured resident weight bytes; the q8/container rows add ``hbm_ratio``
(vs bf16_dequant) and ``tokens_match`` (greedy identity vs bf16_dequant) —
both gated as hard invariants by ``benchmarks.check_regression``.

Run: PYTHONPATH=src python -m benchmarks.serve_bench [--fast]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _weight_bytes(params) -> int:
    """Resident bytes of the loaded serving tree (q8 leaves count their
    int8 levels at 1 B/param + f32 scales — the whole point)."""
    import jax
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(params))


def bench_backend(cfg, weights, backend: str, *, slots: int,
                  prompt_len: int, steps: int, requests: int,
                  label: str | None = None) -> dict:
    import jax
    from repro.serve.session import ServeConfig, ServeSession

    scfg = ServeConfig(slots=slots, max_len=prompt_len + steps)
    t0 = time.time()
    session = ServeSession(cfg, weights, backend=backend, serve_cfg=scfg)
    t_load = time.time() - t0

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
               for _ in range(requests)]

    # warmup: compile the batched prefill/decode/scatter shapes the timed
    # region will hit (same request count and prompt length)
    warm = [session.submit(p, max_new_tokens=2) for p in prompts]
    session.run()
    assert all(w.done for w in warm)
    jax.block_until_ready(session.params)

    t0 = time.time()
    handles = [session.submit(p, max_new_tokens=steps) for p in prompts]
    # admit everything up front (slots >= requests) so the prefill/decode
    # split is clean: _admit runs only prefills (+ first-token sampling)
    session._admit()
    t_prefill_phase = time.time() - t0
    assert session.num_queued == 0, "bench requires slots >= requests"

    t1 = time.time()
    session.run()
    t_decode_phase = time.time() - t1
    assert all(h.done for h in handles)

    prompt_tokens = sum(p.size for p in prompts)
    # one token per request is emitted by prefill; the rest by decode
    first_tokens = len(handles)
    gen_tokens = sum(len(h.tokens) for h in handles) - first_tokens
    total = t_prefill_phase + t_decode_phase
    return {
        "backend": label or backend,
        "slots": slots,
        "requests": requests,
        "prompt_len": prompt_len,
        "steps": steps,
        "load_s": round(t_load, 4),
        "prefill_s": round(t_prefill_phase, 4),
        "decode_s": round(t_decode_phase, 4),
        "prefill_tok_s": round((prompt_tokens + first_tokens)
                               / max(t_prefill_phase, 1e-9), 1),
        "decode_tok_s": round(gen_tokens / max(t_decode_phase, 1e-9), 1),
        "total_tok_s": round((prompt_tokens + first_tokens + gen_tokens)
                             / max(total, 1e-9), 1),
        "weight_hbm_bytes": _weight_bytes(session.params),
        "_tokens": [[int(t) for t in h.tokens] for h in handles],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="BENCH_serve.json")
    args, _ = ap.parse_known_args()

    import jax
    import jax.numpy as jnp
    from repro import compression
    from repro import configs
    from repro.models.transformer import init_params
    from repro.serve.quantized import (dequant_tree,
                                       quantize_params_for_serving)

    cfg = configs.get("llama3-8b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    blob = compression.get("serve-q8").compress(params).blob
    # bf16-resident baseline with q8 numerics: same quantized weights,
    # dequantized once at admission (what serving did before the fused
    # compressed-resident path)
    deq = dequant_tree(quantize_params_for_serving(params),
                       jnp.dtype(cfg.param_dtype))

    steps = 16 if args.fast else 48
    requests = 6 if args.fast else 12
    kw = dict(slots=requests, prompt_len=16, steps=steps, requests=requests)
    rows = [
        bench_backend(cfg, params, "bf16", **kw),
        bench_backend(cfg, deq, "bf16", label="bf16_dequant", **kw),
        bench_backend(cfg, params, "q8", **kw),
        bench_backend(cfg, blob, "container", **kw),
    ]
    base = next(r for r in rows if r["backend"] == "bf16_dequant")
    for r in rows:
        if r["backend"] in ("q8", "container"):
            r["hbm_ratio"] = round(
                r["weight_hbm_bytes"] / base["weight_hbm_bytes"], 4)
            r["tokens_match"] = bool(r["_tokens"] == base["_tokens"])
            r["decode_tok_s_ratio"] = round(
                r["decode_tok_s"] / max(base["decode_tok_s"], 1e-9), 4)
    for r in rows:
        del r["_tokens"]
    report = {"bench": "serve_session_smoke", "arch": cfg.name,
              "fast": bool(args.fast), "rows": rows}
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for r in rows:
        print(f"serve/{r['backend']},{r['total_tok_s']},"
              f"{json.dumps(r, default=float)}", flush=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
