"""Rate-distortion Pareto sweep across the config zoo -> BENCH_rd.json.

For each architecture, runs ``repro.compression.rd_search.rd_sweep``:
the global (delta_rel, lambda) grid is encoded into real lane-scheduled
containers and scored against the uncompressed model through
``ServeSession`` (greedy-token disagreement + last-position logit KL),
the Pareto front is extracted, and the winner is refined per tensor
under a FIM-weighted distortion budget.  Three rows per arch:

* ``pareto``    — every measured grid point with its ``on_front`` flag
  (the per-model RD curve the paper frames as the deployable evidence)
* ``policy``    — the winning :class:`TensorPolicy` table (embedded in
  the row, auditable + reusable via ``get("deepcabac-rd",
  policy_table=row["policy"])``) and its end-to-end measurements
* ``dominance`` — the swept ``deepcabac-rd`` container vs the
  fixed-lambda ``deepcabac-v3`` default (delta_rel=1e-3): byte ratio and
  a hard dominates flag (<= bytes at <= greedy-token error), gated by
  ``benchmarks.check_regression``.

``--fast`` sweeps one dense arch on a small grid (the CI gate); the full
run covers dense + MoE + SSM (the scenario-diversity proof) and joins
the scheduled nightly job.  VLM configs take embeds, not tokens, so the
serving-path distortion proxy skips them.

Run: PYTHONPATH=src python -m benchmarks.rd_sweep_bench [--fast]
"""

from __future__ import annotations

import argparse
import json
import time

FAST_ARCHS = ("llama3-8b",)
FULL_ARCHS = ("llama3-8b", "deepseek-moe-16b", "mamba2-2.7b")
V3_DELTA_REL = 1e-3     # the fixed-lambda deepcabac-v3 default the swept
                        # policy must dominate


def sweep_arch(arch: str, fast: bool) -> list[dict]:
    import jax
    from repro import compression, configs
    from repro.compression.rd_search import RDSearchConfig, TaskProxy, rd_sweep
    from repro.models.transformer import init_params

    cfg = configs.get(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    search = (RDSearchConfig(delta_rels=(1e-3, 6e-3), lambdas=(0.0, 1e-5),
                             prompts=3, decode_steps=6, fim_batches=1)
              if fast else
              RDSearchConfig(delta_rels=(1e-3, 2e-3, 6e-3, 2e-2),
                             lambdas=(0.0, 1e-6, 1e-5, 1e-4)))

    t0 = time.time()
    res = rd_sweep(cfg, params, search)
    sweep_s = time.time() - t0

    # fixed-lambda baseline through the same proxy (same seed -> same
    # prompts as the sweep's own measurements)
    proxy = TaskProxy(cfg, params, prompts=search.prompts,
                      prompt_len=search.prompt_len,
                      decode_steps=search.decode_steps, seed=search.seed)
    v3 = compression.get("deepcabac-v3", delta_rel=V3_DELTA_REL)
    blob = v3.compress(params).blob
    v3_d = proxy.measure(compression.decompress(blob, like=params))

    dominates = (res.policy_bytes <= len(blob)
                 and res.policy_token_err <= v3_d["token_err"])
    return [
        {"path": "pareto", "arch": arch, "family": cfg.family,
         "sweep_s": round(sweep_s, 2),
         "grid": {"delta_rels": list(search.delta_rels),
                  "lambdas": list(search.lambdas)},
         "points": [p.to_dict() for p in res.points],
         "front_size": sum(p.on_front for p in res.points)},
        {"path": "policy", "arch": arch,
         "tensors": len(res.policy.rules),
         "refined": res.refined_tensors, "reverted": res.reverted,
         "bytes": res.policy_bytes,
         "token_err": round(res.policy_token_err, 6),
         "logit_kl": round(res.policy_logit_kl, 8),
         "winner": res.winner.to_dict(),
         "policy": res.policy.to_dict()},
        {"path": "dominance", "arch": arch,
         "rd_bytes": res.policy_bytes,
         "rd_token_err": round(res.policy_token_err, 6),
         "rd_logit_kl": round(res.policy_logit_kl, 8),
         "v3_bytes": len(blob),
         "v3_token_err": round(v3_d["token_err"], 6),
         "v3_logit_kl": round(v3_d["logit_kl"], 8),
         "bytes_ratio": round(res.policy_bytes / max(len(blob), 1), 4),
         "dominates": bool(dominates)},
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--archs", nargs="*", default=None,
                    help="override the arch list")
    ap.add_argument("--out", default="BENCH_rd.json")
    args, _ = ap.parse_known_args()

    archs = args.archs or (FAST_ARCHS if args.fast else FULL_ARCHS)
    rows: list[dict] = []
    for arch in archs:
        rows += sweep_arch(arch, args.fast)
    report = {"bench": "rd_pareto_sweep", "fast": bool(args.fast),
              "archs": list(archs), "rows": rows}
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for r in rows:
        if r["path"] == "dominance":
            print(f"rd/{r['arch']},ratio={r['bytes_ratio']},"
                  f"dominates={r['dominates']},"
                  f"{json.dumps(r, default=float)}", flush=True)
        elif r["path"] == "policy":
            print(f"rd/{r['arch']}/policy,tensors={r['tensors']},"
                  f"refined={r['refined']},bytes={r['bytes']}", flush=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
