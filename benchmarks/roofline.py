"""Roofline report: reads the dry-run JSONs and derives the three terms.

    compute    = HLO_FLOPs_per_device / 197e12        (bf16 MXU peak, v5e)
    memory     = HLO_bytes_per_device / 819e9         (HBM bandwidth)
    collective = wire_bytes_per_device / 50e9         (ICI per-link)

cost_analysis numbers come from the partitioned module, i.e. already
per-device; wire bytes use ring-algorithm estimates per collective kind with
while-loop trip multiplication (see launch/dryrun.py).

MODEL_FLOPS: 6*N*D for train steps (2*N*D for forward-only serve steps),
N = matmul-visible params (embedding gather excluded, head included),
N_active for MoE.  The MODEL/HLO ratio flags remat & redundant compute.

CPU-backend caveat recorded in EXPERIMENTS.md: XLA CPU legalizes some bf16
ops to f32, so HLO bytes (and collective payloads shown as f32) are upper
bounds - on TPU the bf16 payloads halve those terms.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--csv out.csv]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_BYTES = 16 * 2**30

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def _param_counts():
    """N (matmul params) and N_active per arch, from the configs."""
    import jax
    from repro import configs
    from repro.configs import ARCH_IDS
    from repro.models.transformer import init_params
    out = {}
    for arch in ARCH_IDS:
        cfg = configs.get(arch)
        shapes = jax.eval_shape(
            lambda c=cfg: init_params(c, jax.random.PRNGKey(0)))
        flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
        total = active = 0
        for path, leaf in flat:
            keys = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
            n = 1
            for d in leaf.shape:
                n *= d
            if keys == "embed":
                continue           # gather, no matmul flops
            total += n
            if "moe/w_" in keys and "sh_" not in keys:
                # routed experts: only top_k of num_experts active per token
                active += n * cfg.top_k // max(cfg.num_experts, 1)
            else:
                active += n
        out[arch] = {"n": total, "n_active": active}
    return out


def load_cells() -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def derive(cell: dict, counts: dict) -> dict:
    from repro.configs import SHAPES
    seq, batch, kind = SHAPES[cell["shape"]]
    arch = cell["arch"]
    n_chips = cell["n_chips"]
    compute_s = cell["flops_per_device"] / PEAK_FLOPS
    memory_s = cell.get("bytes_per_device_bf16",
                        cell["bytes_per_device"]) / HBM_BW
    wire = cell["collectives"].get("total_wire_bytes",
                                   cell["collectives"]
                                   .get("total_per_device_bytes", 0.0))
    coll_s = wire / LINK_BW
    tokens = batch * (seq if kind != "decode" else 1)
    n = counts[arch]["n_active"]
    factor = 6.0 if kind == "train" else 2.0
    model_flops = factor * n * tokens / n_chips       # per device
    ratio = model_flops / max(cell["flops_per_device"], 1.0)
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    # roofline fraction: useful-compute time over the bounding term
    ideal_compute = model_flops / PEAK_FLOPS
    frac = ideal_compute / bound if bound > 0 else 0.0
    peak_mem = cell["memory"].get("peak_bytes", 0) or \
        cell["memory"]["live_bytes_est"]
    mesh_label = cell["mesh"] + ("+int8" if cell.get("int8_serving") else "")
    return {
        "arch": arch, "shape": cell["shape"], "mesh": mesh_label,
        "chips": n_chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops_dev": model_flops,
        "hlo_flops_dev": cell["flops_per_device"],
        "model_hlo_ratio": ratio,
        "roofline_frac": frac,
        "peak_mem_gb": peak_mem / 2**30,
        "fits_hbm": peak_mem <= HBM_BYTES,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", default=None)
    ap.add_argument("--mesh", default=None,
                    choices=[None, "single", "multi", "single+int8"])
    args = ap.parse_args()
    counts = _param_counts()
    rows = [derive(c, counts) for c in load_cells()]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    hdr = (f"{'arch':18s} {'shape':12s} {'mesh':6s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'coll_s':>10s} {'domin':>6s} "
           f"{'MF/HF':>6s} {'roofl%':>7s} {'mem_GB':>7s} fits")
    print(hdr)
    for r in rows:
        if args.mesh and r["mesh"] != args.mesh:
            continue
        print(f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:6s} "
              f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
              f"{r['collective_s']:10.4f} {r['dominant'][:6]:>6s} "
              f"{r['model_hlo_ratio']:6.2f} {100*r['roofline_frac']:7.1f} "
              f"{r['peak_mem_gb']:7.2f} {'Y' if r['fits_hbm'] else 'N'}")
    if args.csv:
        import csv
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
        print(f"wrote {args.csv}")
    return rows


if __name__ == "__main__":
    main()
