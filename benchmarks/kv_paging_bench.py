"""Paged-KV serving benchmark: concurrent long-context sessions per GiB.

Pins down what the entropy-coded paged cache (``repro.serve.kv``) buys
over the monolithic slot cache on the smoke config:

* **capacity** — how many concurrent long-context sessions one GiB of
  *device* KV sustains.  Slot mode must preallocate ``max_len`` for every
  slot; paged mode holds only each request's written pages hot and parks
  the overflow compressed on host, so the same device budget admits a
  multiple (the ``sessions_per_gib_ratio`` headline — the acceptance bar
  is >= 3x).
* **correctness under pressure** — the paged run uses a pool much
  smaller than ``slots x max_len``, forcing compressed eviction and
  restore mid-generation; its greedy tokens must equal the slot-mode
  run's (``tokens_match``).
* **eviction codec** — compression ratio of evicted pages and the
  restore latency through the lane-parallel batched decoder.

Writes ``BENCH_kv_paging.json`` for the CI regression gate
(``benchmarks/check_regression.py``).  Numbers are host-CPU smoke-scale:
regression tracking, not roofline claims.

Run: PYTHONPATH=src python -m benchmarks.kv_paging_bench [--fast]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

GIB = 1 << 30


def _workload(cfg, requests: int, prompt_len: int, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
            for _ in range(requests)]


def _run(cfg, params, prompts, serve_cfg, steps: int):
    from repro.serve.session import ServeSession
    session = ServeSession(cfg, params, serve_cfg=serve_cfg)
    t0 = time.time()
    handles = [session.submit(p, max_new_tokens=steps) for p in prompts]
    session.run(max_steps=20000)
    wall = time.time() - t0
    assert all(h.done for h in handles), "workload did not finish"
    outs = [list(map(int, h.result())) for h in handles]
    report = session.kv_report()
    session.close()
    return outs, wall, report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="BENCH_kv_paging.json")
    args, _ = ap.parse_known_args()

    import jax
    from repro import configs
    from repro.models.transformer import init_params
    from repro.serve.kv import kv_cache_bytes
    from repro.serve.session import ServeConfig

    # int8 cache: the eviction codec is lossless on the cache levels, so
    # the paged run must be token-identical to slot mode
    cfg = configs.get("llama3-8b", smoke=True).replace(q8_cache=True)
    params = init_params(cfg, jax.random.PRNGKey(0))

    max_len = 128 if args.fast else 256
    steps = 12 if args.fast else 24
    requests = 6 if args.fast else 8
    slots = requests
    page = 8
    prompt_len = max_len // 4
    prompts = _workload(cfg, requests, prompt_len)

    # -- slot mode: device KV is slots x max_len, always resident --------
    slot_cfg = ServeConfig(slots=slots, max_len=max_len)
    ref_out, slot_wall, slot_rep = _run(cfg, params, prompts, slot_cfg,
                                        steps)

    # -- paged mode: hot pool at a quarter of slot mode's device budget —
    # smaller than the workload's working set, so sessions time-share the
    # pool and the overflow lives entropy-coded on host -------------------
    n_max = -(-max_len // page)
    pool_pages = slots * n_max // 4 + 1
    paged_cfg = ServeConfig(slots=slots, max_len=max_len, kv_page_size=page,
                            kv_pool_pages=pool_pages, kv_restore_workers=1)
    paged_out, paged_wall, paged_rep = _run(cfg, params, prompts, paged_cfg,
                                            steps)

    tokens_match = paged_out == ref_out
    sched = paged_rep["scheduler"]
    kv_stats = paged_rep["stats"]

    # sessions per GiB of *device* KV, both modes driving the identical
    # concurrent workload to completion.  One source of truth for the
    # per-session device cost: kv_cache_bytes / the pool's real nbytes.
    bytes_per_slot = kv_cache_bytes(cfg, 1, max_len)
    slot_sessions_per_gib = requests / (slots * bytes_per_slot / GIB)
    paged_sessions_per_gib = requests / (paged_rep["device_bytes"] / GIB)
    ratio = paged_sessions_per_gib / slot_sessions_per_gib

    restore_ms = (1e3 * kv_stats["restore_s"] / max(kv_stats["restores"], 1))
    evict_ratio = (kv_stats["bytes_to_host"]
                   / max(kv_stats["pages_evicted"]
                         * paged_rep["page_bytes"], 1))

    rows = [{
        "path": "capacity",
        "requests": requests, "max_len": max_len, "steps": steps,
        "page_size": page, "pool_pages": pool_pages,
        "bytes_per_slot": bytes_per_slot,
        "slot_device_bytes": slots * bytes_per_slot,
        "paged_device_bytes": paged_rep["device_bytes"],
        "slot_sessions_per_gib": round(slot_sessions_per_gib, 1),
        "paged_sessions_per_gib": round(paged_sessions_per_gib, 1),
        "sessions_per_gib_ratio": round(ratio, 2),
        "tokens_match": tokens_match,
        "slot_wall_s": round(slot_wall, 3),
        "paged_wall_s": round(paged_wall, 3),
    }, {
        "path": "evict_restore",
        "parks": sched["parks"], "resumes": sched["resumes"],
        "pages_evicted": kv_stats["pages_evicted"],
        "pages_restored": kv_stats["pages_restored"],
        "bytes_to_host": kv_stats["bytes_to_host"],
        "evicted_compression_ratio": round(evict_ratio, 4),
        "restore_ms_mean": round(restore_ms, 3),
        "prefix_hits": kv_stats["prefix_hits"],
        "free_slot_rows": sched["free_slot_rows"],
        "padded_rows": sched["padded_rows"],
    }]
    report = {"bench": "kv_paging", "arch": cfg.name,
              "fast": bool(args.fast), "rows": rows}
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for r in rows:
        print(f"kv_paging/{r['path']},{json.dumps(r, default=float)}",
              flush=True)
    print(f"wrote {args.out}")
    if not tokens_match:
        raise SystemExit("paged tokens diverged from slot mode")
    if ratio < 3.0:
        raise SystemExit(
            f"sessions_per_gib_ratio {ratio:.2f} < 3.0 acceptance bar")


if __name__ == "__main__":
    main()
