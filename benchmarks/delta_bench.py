"""Temporal-delta ("P-frame") checkpoint benchmark.

Encodes the smoke model twice — a pruned base frame and a realistically
drifted next frame (small multiplicative drift plus sub-step noise on
the surviving weights, zeros preserved) — and measures

* P-frame bytes vs a full I-frame re-encode of the same step-locked
  frame (the storage payoff of residual coding; the gate requires
  <= 0.35x),
* temporal-context CABAC vs intra-only coding of the *same* residuals
  (the payoff of conditioning context banks on the co-located base
  level; must come in strictly below 1.0),
* live ``ServeSession.swap_weights`` latency vs a cold serving start
  from the full container (the serving payoff: only residual decode +
  in-place patch, no session rebuild).

Writes ``BENCH_delta.json`` (same trajectory contract as the other
benches); benchmarks/check_regression.py gates the ratios and the swap
latency against the committed baseline.

Run: PYTHONPATH=src python -m benchmarks.delta_bench [--fast]
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np


def _params(prune: float, seed: int = 0):
    import jax
    from repro import configs
    from repro.models.transformer import init_params

    cfg = configs.get("llama3-8b", smoke=True)
    params = jax.device_get(init_params(cfg, jax.random.PRNGKey(seed)))
    from repro.compression import flatten_tree
    rng = np.random.default_rng(seed)
    flat = {}
    for k, v in flatten_tree(params).items():
        v = np.asarray(v)
        if v.dtype.kind == "f" and v.ndim >= 2:
            # magnitude pruning stands in for the sparse networks the
            # paper compresses; the drift model below keeps zeros zero
            mask = rng.random(v.shape) >= prune
            v = (v * mask).astype(v.dtype)
        flat[k] = v
    return cfg, flat


def _drift(flat: dict, steps: dict, seed: int) -> dict:
    """One optimizer step of drift: ~1e-4 relative change plus sub-step
    noise on nonzero weights (residuals land mostly in {-1, 0, 1} on the
    base grid), pruned zeros stay exactly zero."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, v in flat.items():
        v = np.asarray(v)
        if v.dtype.kind == "f" and k in steps:
            noise = (v * 1e-4 * rng.standard_normal(v.shape)
                     + steps[k] * 0.3 * rng.standard_normal(v.shape)
                     * (v != 0))
            out[k] = (v + noise).astype(v.dtype)
        else:
            out[k] = v
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="BENCH_delta.json")
    ap.add_argument("--prune", type=float, default=0.3)
    args = ap.parse_args()

    from repro import compression
    from repro.checkpoint import CheckpointConfig, CheckpointManager
    from repro.core.codec import DeltaTensor, encode_level_chunks_batched
    from repro.serve.backends import get_backend
    from repro.serve.session import ServeConfig, ServeSession

    cfg, flat = _params(args.prune)
    codec = compression.get("deepcabac-delta", delta_rel=1e-3)
    reps = 1 if args.fast else 3

    base_art = codec.compress(flat)
    base_entries = base_art.quantized
    kf_bytes = len(base_art.blob)
    steps = {k: e.step for k, e in base_entries.items()
             if hasattr(e, "step")}
    flat2 = _drift(flat, steps, seed=1)

    # -- P-frame vs full re-encode of the same step-locked frame -----------
    dentries = codec.delta_entries(flat2, base_entries)
    delta_art = codec.compress_delta(flat2, base_entries)
    delta_bytes = len(delta_art.blob)
    full_bytes = len(codec.compress_entries(
        codec.quantize_like(flat2, base_entries)).blob)

    # -- temporal-context vs intra coding of the same residuals ------------
    tc_bytes = intra_bytes = 0
    coder = codec.coder
    for e in dentries.values():
        if not isinstance(e, DeltaTensor):
            continue
        from repro.core.codec import encode_delta_chunks_batched
        tc = encode_delta_chunks_batched(e.resid.ravel(), e.base.ravel(),
                                         coder.num_gr, coder.chunk_size)[0]
        intra = encode_level_chunks_batched(e.resid.ravel(), coder.num_gr,
                                            coder.chunk_size)[0]
        tc_bytes += sum(len(p) for p in tc)
        intra_bytes += sum(len(p) for p in intra)

    # -- swap latency vs cold start -----------------------------------------
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(CheckpointConfig(
            td, keep=4, codec="deepcabac-delta", delta_every=4))
        mgr.save({"params": flat}, 1)
        mgr.save({"params": flat2}, 2)
        kf_dir = os.path.join(td, "step_00000001")
        delta_dir = os.path.join(td, "step_00000002")
        with open(os.path.join(kf_dir, "params.dcbc"), "rb") as f:
            kf_blob = f.read()
        full_blob = codec.compress_entries(
            codec.quantize_like(flat2, base_entries)).blob

        serve_cfg = ServeConfig(slots=2, max_len=32)
        cold_best = float("inf")
        for _ in range(reps):
            t0 = time.time()
            ServeSession(cfg, full_blob, backend="container",
                         serve_cfg=serve_cfg)
            cold_best = min(cold_best, time.time() - t0)

        swap_best, swapped = float("inf"), 0
        for _ in range(reps):
            backend = get_backend("container", track_levels=True)
            session = ServeSession(cfg, kf_blob, backend=backend,
                                   serve_cfg=serve_cfg)
            t0 = time.time()
            swapped = session.swap_weights(delta_dir)
            swap_best = min(swap_best, time.time() - t0)

    rows = [
        {"path": "p_frame",
         "bytes": delta_bytes,
         "keyframe_bytes": kf_bytes,
         "full_bytes": full_bytes,
         "ratio_vs_full": round(delta_bytes / max(full_bytes, 1), 4),
         "tc_bytes": tc_bytes,
         "intra_bytes": intra_bytes,
         "tc_vs_intra": round(tc_bytes / max(intra_bytes, 1), 4)},
        {"path": "swap",
         "swap_s": round(swap_best, 4),
         "cold_start_s": round(cold_best, 4),
         "swapped_tensors": swapped,
         "speedup_vs_cold": round(cold_best / max(swap_best, 1e-9), 2)},
    ]
    report = {
        "bench": "delta",
        "arch": cfg.name,
        "fast": bool(args.fast),
        "prune": args.prune,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for r in rows:
        print(f"delta/{r['path']},{json.dumps(r, default=float)}",
              flush=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
