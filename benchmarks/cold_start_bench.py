"""Container cold-start decode benchmark: lane-parallel vs serial CABAC.

ServeSession cold-start from a ``container`` backend (and checkpoint
restore) is bottlenecked on entropy decode.  This bench compresses a full
model state dict with ``deepcabac-v3`` and measures whole-container decode
through ``decode_state_dict_batched`` — the serial per-chunk scalar loop
as the baseline, then the lane engine over a 1/8/64 lane sweep, the
portable numpy lockstep engine, and the residual scalar path on a worker
pool.  Writes ``BENCH_cold_start.json`` so CI accumulates a trajectory
(same contract as BENCH_serve/BENCH_kernels).

Run: PYTHONPATH=src python -m benchmarks.cold_start_bench [--fast]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _state_dict(copies: int):
    import jax
    from repro import configs
    from repro.models.transformer import init_params

    cfg = configs.get("llama3-8b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    if copies == 1:
        return cfg, params
    return cfg, {f"rep{i}": params for i in range(copies)}


def _decode_stats(blob: bytes) -> tuple[int, int]:
    """(entropy-coded values, original-dtype bytes) in the container."""
    from repro.core.codec import resolve_dtype
    from repro.core.container import ENC_CABAC, ENC_CABAC_V3, ContainerReader

    vals = nbytes = 0
    for hdr, _ in ContainerReader(blob):
        if hdr.encoding in (ENC_CABAC, ENC_CABAC_V3):
            n = int(np.prod(hdr.shape)) if hdr.shape else 1
            vals += n
            nbytes += n * resolve_dtype(hdr.dtype).itemsize
    return vals, nbytes


def bench_row(blob: bytes, vals: int, nbytes: int, *, engine: str,
              lanes: int, workers: int = 0, pool: str = "thread",
              reps: int = 1, serial_s: float | None = None) -> dict:
    from repro.core.codec import DecodeOptions, decode_state_dict_batched

    opts = DecodeOptions(lanes=lanes, backend=engine, workers=workers,
                         pool=pool)
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        out = decode_state_dict_batched(blob, dequantize=False, opts=opts)
        best = min(best, time.time() - t0)
    assert out
    row = {
        "engine": engine if not workers else f"{engine}+{pool}pool{workers}",
        "lanes": lanes,
        "decode_s": round(best, 4),
        "values_per_s": round(vals / max(best, 1e-9), 1),
        "mb_per_s": round(nbytes / 2**20 / max(best, 1e-9), 2),
    }
    if serial_s is not None:
        row["speedup_vs_serial"] = round(serial_s / max(best, 1e-9), 2)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="BENCH_cold_start.json")
    ap.add_argument("--copies", type=int, default=None,
                    help="state-dict replication factor (default 4, 1 fast)")
    args = ap.parse_args()

    from repro import compression
    from repro.core.cabac_vec import available_backends
    from repro.core.container import ContainerReader

    copies = args.copies or (1 if args.fast else 4)
    chunk_size = 2048 if args.fast else 4096
    cfg, tree = _state_dict(copies)
    codec = compression.get("deepcabac-v3", delta_rel=1e-3,
                            chunk_size=chunk_size)
    blob = codec.compress(tree).blob
    vals, nbytes = _decode_stats(blob)
    reps = 1 if args.fast else 2

    # Baseline: the serial per-chunk scalar loop (the pre-v3 decode path).
    serial = bench_row(blob, vals, nbytes, engine="scalar", lanes=1,
                       reps=reps)
    serial_s = serial["decode_s"]
    serial["speedup_vs_serial"] = 1.0

    rows = [serial]
    rows.append(bench_row(blob, vals, nbytes, engine="scalar", lanes=1,
                          workers=2, pool="process", reps=reps,
                          serial_s=serial_s))
    for lanes in (1, 8, 64):
        rows.append(bench_row(blob, vals, nbytes, engine="auto",
                              lanes=lanes, reps=reps, serial_s=serial_s))
    if "c" in available_backends() and not args.fast:
        # The portable numpy lockstep engine, reported separately for
        # honesty: its per-step numpy dispatch overhead amortizes over
        # lanes, so it needs wide batches (~512 on slow hosts) to beat
        # the serial loop — the C kernel wins at any width.
        for lanes in (64, 512):
            rows.append(bench_row(blob, vals, nbytes, engine="numpy",
                                  lanes=lanes, reps=1, serial_s=serial_s))

    report = {
        "bench": "container_cold_start_decode",
        "arch": cfg.name,
        "fast": bool(args.fast),
        "copies": copies,
        "chunk_size": chunk_size,
        "container_version": ContainerReader(blob).version,
        "entropy_coded_values": vals,
        "decoded_mb": round(nbytes / 2**20, 2),
        "compressed_mb": round(len(blob) / 2**20, 2),
        "lane_engines": available_backends(),
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for r in rows:
        print(f"cold_start/{r['engine']}@{r['lanes']},"
              f"{r['values_per_s']},{json.dumps(r, default=float)}",
              flush=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
